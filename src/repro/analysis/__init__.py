"""`repro.analysis` — project-specific static checks, wired as a CI gate.

Five AST-based checkers encode the invariants this codebase actually
depends on but that no generic linter knows about:

  lock-discipline   attrs annotated `# guarded-by: _lock` are only touched
                    inside the matching `with self._lock:` block
  kernel-contract   every Pallas kernel module exports an ops.py wrapper
                    and a pure-JAX ref.py oracle, resolves tiles at call
                    time, and keeps float64 / nondeterminism out of bodies
  host-sync         no hidden device synchronisation (`.item()`, `float()`,
                    `np.asarray`, `block_until_ready`) in engine/admission/
                    kernel hot paths outside `obs.fence()`
  knob-registry     every `REPRO_*` env read goes through `repro.knobs`
                    and every knob is registered + documented
  instrument-drift  metric/span names emitted via `repro.obs` match the
                    docs/observability.md catalogue bidirectionally

Audited exceptions carry an inline pragma with a reason:

    something_suspicious()  # repro: allow[host-sync] summary path is cold

Run the suite with `PYTHONPATH=src python scripts/check.py --all`; the
tier-1 test `tests/test_analysis.py::test_repo_is_clean` keeps the merged
tree at zero unallowed violations.
"""
from __future__ import annotations

from .base import Project, SourceFile, Violation
from .runner import CHECKERS, run, run_all

__all__ = ["CHECKERS", "Project", "SourceFile", "Violation", "run",
           "run_all"]
