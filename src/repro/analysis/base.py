"""Shared infrastructure for the static checkers.

A checker is a callable `(Project) -> List[Violation]`.  `Project` loads
and caches parsed source files; `SourceFile` carries the AST plus the
line-indexed `# repro: allow[...]` pragmas, and pragma application happens
once in `apply_pragmas` so individual checkers never re-implement
suppression.

Pragma grammar (one per line, trailing comment or own line directly above
the flagged statement):

    # repro: allow[check-id] <reason — mandatory, it is the audit trail>

A pragma with no reason does not suppress anything; the runner reports it
as a `pragma` violation instead, so un-justified exceptions cannot slip
through review.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([a-z0-9-]+)\]\s*(.*)")


@dataclass
class Violation:
    """One finding: where, which checker, and what is wrong."""

    check: str
    path: str                 # repo-relative, posix separators
    line: int
    message: str
    allowed: bool = False     # True once a pragma with a reason covers it
    reason: str = ""          # the pragma's justification, when allowed

    def format(self) -> str:
        mark = " (allowed: %s)" % self.reason if self.allowed else ""
        return f"{self.path}:{self.line}: [{self.check}] {self.message}{mark}"


@dataclass
class Pragma:
    line: int
    check: str
    reason: str


@dataclass
class SourceFile:
    """One parsed python file: text, AST, and its allow-pragmas by line."""

    path: Path
    rel: str
    text: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    pragmas: List[Pragma] = field(default_factory=list)
    comments: Dict[int, str] = field(default_factory=dict)  # line -> text

    @classmethod
    def parse(cls, path: Path, rel: str) -> "SourceFile":
        text = path.read_text(encoding="utf-8")
        tree = ast.parse(text, filename=str(path))
        lines = text.splitlines()
        # real COMMENT tokens only — a docstring showing pragma syntax must
        # not register as a pragma
        comments: Dict[int, str] = {}
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string
        pragmas = []
        for line_no, comment in sorted(comments.items()):
            m = PRAGMA_RE.search(comment)
            if m:
                pragmas.append(Pragma(line_no, m.group(1),
                                      m.group(2).strip()))
        return cls(path, rel, text, tree, lines, pragmas, comments)

    def pragma_for(self, check: str, line: int) -> Optional[Pragma]:
        """The pragma covering `line` for `check`: same line or the line
        directly above (an own-line pragma annotating the next statement)."""
        for p in self.pragmas:
            if p.check == check and p.line in (line, line - 1):
                return p
        return None


class Project:
    """The file universe one run sees.  `roots` are directories (searched
    recursively for *.py) or single files; paths are cached so the five
    checkers parse each file once."""

    def __init__(self, root: Path, roots: Iterable[str] = ("src",)):
        self.root = Path(root)
        self.roots = tuple(roots)
        self._cache: Dict[str, SourceFile] = {}

    def files(self, under: str = "") -> List[SourceFile]:
        out = []
        for top in self.roots:
            base = self.root / top
            if base.is_file():
                paths = [base]
            else:
                paths = sorted(base.rglob("*.py"))
            for path in paths:
                rel = path.relative_to(self.root).as_posix()
                if under and not rel.startswith(under):
                    continue
                if rel not in self._cache:
                    self._cache[rel] = SourceFile.parse(path, rel)
                out.append(self._cache[rel])
        return out

    def get(self, rel: str) -> Optional[SourceFile]:
        """One file by repo-relative path, or None if absent."""
        if rel in self._cache:
            return self._cache[rel]
        path = self.root / rel
        if not rel.endswith(".py") or not path.is_file():
            return None
        self._cache[rel] = SourceFile.parse(path, rel)
        return self._cache[rel]


def apply_pragmas(project: Project,
                  violations: List[Violation]) -> Tuple[List[Violation],
                                                        List[Violation]]:
    """Split raw findings into (unallowed, allowed) by consulting each
    file's pragmas.  Pragmas with an empty reason never suppress; the
    runner surfaces them separately (`check="pragma"`)."""
    unallowed, allowed = [], []
    for v in violations:
        sf = project.get(v.path)
        p = sf.pragma_for(v.check, v.line) if sf else None
        if p is not None and p.reason:
            v.allowed, v.reason = True, p.reason
            allowed.append(v)
        else:
            unallowed.append(v)
    return unallowed, allowed


def bare_pragma_violations(project: Project,
                           check_ids: Iterable[str]) -> List[Violation]:
    """Reason-less or unknown-id pragmas are findings themselves: the
    pragma IS the audit record, so an empty one defeats the point."""
    known = set(check_ids)
    out = []
    for sf in project.files():
        for p in sf.pragmas:
            if p.check not in known:
                out.append(Violation(
                    "pragma", sf.rel, p.line,
                    f"allow[{p.check}] names no known checker "
                    f"(have: {', '.join(sorted(known))})"))
            elif not p.reason:
                out.append(Violation(
                    "pragma", sf.rel, p.line,
                    f"allow[{p.check}] pragma has no reason — the reason is "
                    f"the audit trail, add one"))
    return out


def attr_chain(node: ast.AST) -> str:
    """Dotted name for Attribute/Name chains: `os.environ.get` ->
    'os.environ.get'; '' when the chain bottoms out in a non-Name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_leaf(node: ast.Call) -> str:
    """The called method/function name regardless of what it hangs off:
    `obs.get_registry().histogram(...)` -> 'histogram'."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def str_const(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
