"""instrument-drift: emitted metric/span names == documented names.

The observability surface is an API: dashboards, the validate_metrics.py
schema checker, and the autotuner all key on literal instrument names.  A
renamed counter that ships without a docs update silently breaks all
three, so this checker diffs — bidirectionally —

  * every literal name passed to ``.counter("…")`` / ``.gauge("…")`` /
    ``.histogram("…")`` in src/ and benchmarks/ against the metric
    catalogue tables in ``docs/observability.md``,
  * every literal ``span("…")`` name against the span catalogue, and
  * every instrument literal inside ``scripts/validate_metrics.py``
    against the documented set (the validator must not check phantom
    names).

Dynamic (non-literal) instrument names defeat the diff entirely and are
flagged unless pragma'd.  The ``repro.obs`` package itself is plumbing,
not an emission site, and is excluded.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set, Tuple

from .base import Project, Violation, call_leaf, str_const

CHECK = "instrument-drift"

DOCS_REL = "docs/observability.md"
VALIDATOR_REL = "scripts/validate_metrics.py"
OBS_DIR = "src/repro/obs/"

EMITTERS = {"counter", "gauge", "histogram"}
BACKTICKED = re.compile(r"`([a-z_]+(?:\.[a-z_]+)+)`")
DOTTED = re.compile(r"^[a-z_]+(?:\.[a-z_]+)+$")


def _doc_catalogue(project: Project,
                   docs_rel: str) -> Tuple[Set[str], Set[str], bool]:
    """(metric names, span names, found) from the docs tables: backticked
    dotted names in table rows, classified by the enclosing ## heading."""
    path = project.root / docs_rel
    if not path.is_file():
        return set(), set(), False
    metrics: Set[str] = set()
    spans: Set[str] = set()
    section = ""
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.startswith("#"):
            section = line.lower()
            continue
        if not line.lstrip().startswith("|"):
            continue
        names = BACKTICKED.findall(line)
        if not names:
            continue
        if "span" in section:
            spans.update(names)
        elif "metric" in section:
            metrics.update(names)
    return metrics, spans, True


def _emissions(project: Project) -> Tuple[Dict[str, Tuple[str, int]],
                                          Dict[str, Tuple[str, int]],
                                          List[Violation]]:
    metrics: Dict[str, Tuple[str, int]] = {}
    spans: Dict[str, Tuple[str, int]] = {}
    out: List[Violation] = []
    for sf in project.files():
        if sf.rel.startswith(OBS_DIR) or sf.rel.startswith("scripts/"):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            leaf = call_leaf(node)
            if leaf in EMITTERS and node.args:
                name = str_const(node.args[0])
                if name is None:
                    out.append(Violation(
                        CHECK, sf.rel, node.lineno,
                        f".{leaf}(<dynamic name>) — non-literal instrument "
                        f"names cannot be checked against the catalogue"))
                else:
                    metrics.setdefault(name, (sf.rel, node.lineno))
            elif leaf == "span" and node.args:
                name = str_const(node.args[0])
                if name is None:
                    out.append(Violation(
                        CHECK, sf.rel, node.lineno,
                        "span(<dynamic name>) — non-literal span names "
                        "cannot be checked against the catalogue"))
                else:
                    spans.setdefault(name, (sf.rel, node.lineno))
    return metrics, spans, out


def check(project: Project, docs_rel: str = DOCS_REL) -> List[Violation]:
    metrics, spans, out = _emissions(project)
    doc_metrics, doc_spans, found = _doc_catalogue(project, docs_rel)
    if not found:
        out.append(Violation(CHECK, docs_rel, 1,
                             f"{docs_rel} is missing — the instrument "
                             f"catalogue is the drift baseline"))
        return out

    for name, (rel, line) in sorted(metrics.items()):
        if name not in doc_metrics:
            out.append(Violation(
                CHECK, rel, line,
                f"metric `{name}` is emitted but missing from the "
                f"{docs_rel} catalogue"))
    for name in sorted(doc_metrics - set(metrics)):
        out.append(Violation(
            CHECK, docs_rel, 1,
            f"metric `{name}` is documented but nothing emits it"))
    for name, (rel, line) in sorted(spans.items()):
        if name not in doc_spans:
            out.append(Violation(
                CHECK, rel, line,
                f"span `{name}` is emitted but missing from the {docs_rel} "
                f"span catalogue"))
    for name in sorted(doc_spans - set(spans)):
        out.append(Violation(
            CHECK, docs_rel, 1,
            f"span `{name}` is documented but nothing opens it"))

    validator = project.get(VALIDATOR_REL)
    if validator is not None:
        documented = doc_metrics | doc_spans
        for node in ast.walk(validator.tree):
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, str) and DOTTED.match(node.value):
                if node.value not in documented:
                    out.append(Violation(
                        CHECK, VALIDATOR_REL, node.lineno,
                        f"validator references `{node.value}` which is not "
                        f"in the {docs_rel} catalogue"))
    return out
