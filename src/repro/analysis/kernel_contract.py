"""kernel-contract: every Pallas kernel module honours the repo's kernel
packaging rules.

A *kernel module* is any file under ``src/repro/kernels/`` whose source
calls ``pl.pallas_call`` (the plumbing modules — ops, ref, tuning,
autotune, triangle — are exempt by construction: they don't).  For each
kernel module:

  1. every public function has a same-named pure-JAX oracle in
     ``kernels/ref.py`` (the numerics contract tests diff against), and
  2. a same-named public wrapper in ``kernels/ops.py`` (the only entry
     point the engine may import), and
  3. no function parameter defaults to a ``*TILE`` constant — tiles are
     resolved via ``tuning.resolve_tile`` at CALL time; an import-time
     default freezes the value before a sweep or env change can move it
     (the PR-9 regression class), so the module must also actually call
     ``resolve_tile`` inside a function body, and
  4. kernel bodies (functions taking ``*_ref`` params — the code that runs
     on device) contain no float64 literals/dtypes and no nondeterminism
     (time/datetime/random calls): TPUs demote f64 silently and a
     nondeterministic kernel can never be diffed against its oracle.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .base import Project, SourceFile, Violation, attr_chain

CHECK = "kernel-contract"

KERNELS_DIR = "src/repro/kernels/"
OPS_REL = "src/repro/kernels/ops.py"
REF_REL = "src/repro/kernels/ref.py"

NONDET_PREFIXES = ("time.", "datetime.", "random.", "np.random.",
                   "numpy.random.", "secrets.")


def _top_level_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body if isinstance(n, ast.FunctionDef)]


def _is_kernel_module(sf: SourceFile) -> bool:
    return (sf.rel.startswith(KERNELS_DIR)
            and "pallas_call" in sf.text
            and sf.rel not in (OPS_REL, REF_REL))


def _tile_default(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id.endswith("TILE"):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr.endswith("TILE"):
        return attr_chain(node)
    return None


def _kernel_bodies(tree: ast.Module) -> List[ast.FunctionDef]:
    out = []
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef):
            params = fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            if any(a.arg.endswith("_ref") for a in params):
                out.append(fn)
    return out


def _check_kernel_body(sf: SourceFile, fn: ast.FunctionDef,
                       out: List[Violation]) -> None:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            out.append(Violation(
                CHECK, sf.rel, node.lineno,
                f"float64 in kernel body {fn.name}(): TPUs silently demote "
                f"f64 — keep kernel numerics f32"))
        elif (isinstance(node, ast.Constant) and node.value == "float64"):
            out.append(Violation(
                CHECK, sf.rel, node.lineno,
                f'"float64" dtype string in kernel body {fn.name}()'))
        elif isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and any(chain.startswith(p) for p in NONDET_PREFIXES):
                out.append(Violation(
                    CHECK, sf.rel, node.lineno,
                    f"nondeterministic call {chain}() in kernel body "
                    f"{fn.name}(): kernels must be diffable against their "
                    f"ref.py oracle"))


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    ops = project.get(OPS_REL)
    ref = project.get(REF_REL)
    ops_names: Set[str] = (
        {f.name for f in _top_level_defs(ops.tree)} if ops else set())
    ref_names: Set[str] = (
        {f.name for f in _top_level_defs(ref.tree)} if ref else set())

    for sf in project.files(KERNELS_DIR):
        if not _is_kernel_module(sf):
            continue

        publics = [f for f in _top_level_defs(sf.tree)
                   if not f.name.startswith("_")]
        for fn in publics:
            if fn.name not in ref_names:
                out.append(Violation(
                    CHECK, sf.rel, fn.lineno,
                    f"public kernel {fn.name}() has no pure-JAX oracle in "
                    f"kernels/ref.py"))
            if fn.name not in ops_names:
                out.append(Violation(
                    CHECK, sf.rel, fn.lineno,
                    f"public kernel {fn.name}() has no wrapper in "
                    f"kernels/ops.py (the engine-facing entry point)"))

        resolves_in_fn = False
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            args = fn.args
            for arg, default in zip(
                    (args.posonlyargs + args.args)[-len(args.defaults):]
                    if args.defaults else [],
                    args.defaults):
                name = _tile_default(default)
                if name:
                    out.append(Violation(
                        CHECK, sf.rel, fn.lineno,
                        f"{fn.name}() defaults {arg.arg}={name} at import "
                        f"time — resolve tiles via tuning.resolve_tile at "
                        f"call time instead"))
            for kwarg, default in zip(args.kwonlyargs, args.kw_defaults):
                name = _tile_default(default) if default is not None else None
                if name:
                    out.append(Violation(
                        CHECK, sf.rel, fn.lineno,
                        f"{fn.name}() defaults {kwarg.arg}={name} at import "
                        f"time — resolve tiles via tuning.resolve_tile at "
                        f"call time instead"))
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    chain = attr_chain(node.func)
                    if chain.endswith("resolve_tile"):
                        resolves_in_fn = True

        if publics and not resolves_in_fn:
            out.append(Violation(
                CHECK, sf.rel, publics[0].lineno,
                f"kernel module never calls tuning.resolve_tile inside a "
                f"function — tile sizes cannot be call-time tuned"))

        for fn in _kernel_bodies(sf.tree):
            _check_kernel_body(sf, fn, out)
    return out
