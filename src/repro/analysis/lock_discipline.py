"""lock-discipline: guarded-by annotations are enforced, not aspirational.

Annotate the attribute's assignment site (normally in ``__init__``)::

    self._entries = {}          # guarded-by: _lock
    self.columns = {}           # guarded-by: _write_lock (writes)

and from then on every ``self._entries`` access anywhere in the class must
sit inside a ``with self._lock:`` block.  ``(writes)`` restricts the rule
to mutations (Store/Del/AugStore and ``self.attr[...] = ...`` /
``self.attr.append(...)``-style mutation through a subscript store) for
attrs whose unlocked reads are by design (e.g. snapshot paths that
tolerate torn reads).

Extras that match how this codebase actually locks:

  * ``self._wakeup = threading.Condition(self._lock)`` is auto-detected as
    an alias — holding ``_wakeup`` counts as holding ``_lock``.
  * A comma list (``# guarded-by: _lock, _write_lock``) means any one of
    the named locks satisfies the guard.
  * A ``# guarded-by: _lock`` comment on a ``def`` line marks a private
    method whose callers hold the lock; its whole body is treated as
    lock-held.  ``__init__`` is exempt (construction happens-before
    publication).
  * Nested functions (closures, thread targets) do NOT inherit the
    enclosing lock state: they may run after the block exits.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set

from .base import Project, SourceFile, Violation

CHECK = "lock-discipline"

GUARDED_RE = re.compile(
    r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*(?:\s*,\s*"
    r"[A-Za-z_][A-Za-z0-9_]*)*)\s*(\(writes\))?")


@dataclass
class Guard:
    locks: FrozenSet[str]
    writes_only: bool
    decl_line: int


@dataclass
class ClassSpec:
    guards: Dict[str, Guard] = field(default_factory=dict)
    aliases: Dict[str, str] = field(default_factory=dict)  # wrapper -> lock

    @property
    def lock_names(self) -> Set[str]:
        names = set(self.aliases)
        for g in self.guards.values():
            names |= g.locks
        return names


def _line_guard(sf: SourceFile, line: int) -> Optional[re.Match]:
    comment = sf.comments.get(line)
    return GUARDED_RE.search(comment) if comment else None


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self"):
        return node.attr
    return None


def _collect_spec(sf: SourceFile, cls: ast.ClassDef) -> ClassSpec:
    spec = ClassSpec()
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            attrs = [a for a in map(_self_attr, targets) if a]
            if not attrs:
                continue
            m = _line_guard(sf, node.lineno)
            if m:
                locks = frozenset(s.strip() for s in m.group(1).split(","))
                for attr in attrs:
                    spec.guards[attr] = Guard(locks, bool(m.group(2)),
                                              node.lineno)
            # self._wakeup = threading.Condition(self._lock): alias detect
            value = getattr(node, "value", None)
            if (isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Attribute)
                    and value.func.attr == "Condition" and value.args):
                inner = _self_attr(value.args[0])
                if inner:
                    for attr in attrs:
                        spec.aliases[attr] = inner
    return spec


class _MethodVisitor(ast.NodeVisitor):
    """Walk one method body tracking the set of held locks."""

    def __init__(self, sf: SourceFile, spec: ClassSpec, method: str,
                 held: Set[str], out: List[Violation]):
        self.sf = sf
        self.spec = spec
        self.method = method
        self.held = set(held)
        self.out = out

    def _expanded_held(self) -> Set[str]:
        held = set(self.held)
        held |= {self.spec.aliases[h] for h in self.held
                 if h in self.spec.aliases}
        return held

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr and attr in self.spec.lock_names:
                acquired.append(attr)
        self.held |= set(acquired)
        for stmt in node.body:
            self.visit(stmt)
        self.held -= set(acquired)
        # re-visit the context expressions themselves (lock attrs are not
        # guarded, but a guarded attr could appear in an `as` clause)
        for item in node.items:
            if item.optional_vars is not None:
                self.visit(item.optional_vars)

    def _visit_nested_def(self, node) -> None:
        # closures / thread targets may outlive the lock scope: reset held
        m = _line_guard(self.sf, node.lineno)
        held = (set(s.strip() for s in m.group(1).split(",")) if m else set())
        sub = _MethodVisitor(self.sf, self.spec, f"{self.method}.{node.name}",
                             held, self.out)
        for stmt in node.body:
            sub.visit(stmt)

    visit_FunctionDef = _visit_nested_def
    visit_AsyncFunctionDef = _visit_nested_def

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        guard = self.spec.guards.get(attr) if attr else None
        if guard is not None and node.lineno != guard.decl_line:
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            if not (guard.writes_only and not is_write and not self._mutates(node)):
                if not (guard.locks & self._expanded_held()):
                    want = " or ".join(f"self.{l}" for l in sorted(guard.locks))
                    self.out.append(Violation(
                        CHECK, self.sf.rel, node.lineno,
                        f"self.{attr} accessed in {self.method}() outside "
                        f"`with {want}` (guarded-by annotation at line "
                        f"{guard.decl_line})"))
        self.generic_visit(node)

    def _mutates(self, node: ast.Attribute) -> bool:
        """True for `self.attr[...] = v` / `del self.attr[...]` — the attr
        itself is ctx=Load but the container is being mutated."""
        parent = getattr(node, "_parent", None)
        return (isinstance(parent, ast.Subscript)
                and isinstance(parent.ctx, (ast.Store, ast.Del)))


def _link_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    for sf in project.files("src/"):
        if "# guarded-by:" not in sf.text:
            continue
        _link_parents(sf.tree)
        for cls in [n for n in ast.walk(sf.tree)
                    if isinstance(n, ast.ClassDef)]:
            spec = _collect_spec(sf, cls)
            if not spec.guards:
                continue
            for item in cls.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name == "__init__":
                    continue
                m = _line_guard(sf, item.lineno)
                held = (set(s.strip() for s in m.group(1).split(","))
                        if m else set())
                visitor = _MethodVisitor(sf, spec, item.name, held, out)
                for stmt in item.body:
                    visitor.visit(stmt)
    return out
