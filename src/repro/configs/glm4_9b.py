"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
Source: [hf:THUDM/glm-4-9b; hf] — RoPE (partial, 50%), extreme GQA (kv=2),
QKV bias.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096, n_heads=32,
    n_kv_heads=2, d_ff=13696, vocab_size=151552, qkv_bias=True,
    partial_rotary=0.5, source="hf:THUDM/glm-4-9b; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="glm4-9b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, qkv_bias=True, partial_rotary=0.5,
    q_chunk=32,
)
