"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024, ssm_state=16.
Source: [arXiv:2410.05355; unverified] — Mamba-1 architecture (selective scan),
expand=2 (d_inner=8192), d_conv=4.  Sub-quadratic: runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    vocab_size=65024, ssm_state=16, d_conv=4, expand=2,
    source="arXiv:2410.05355; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm", n_layers=2, d_model=64,
    vocab_size=256, ssm_state=8, d_conv=4, expand=2,
)
