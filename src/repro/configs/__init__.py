from .base import (ARCH_IDS, SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig,
                   all_cells, cell_runnable, get_config)
