"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec.
Source: [arXiv:2212.04356; unverified].  The conv/mel frontend is a STUB:
input_specs provides precomputed frame embeddings (B, 1500, 512) for the
encoder; shapes' seq_len applies to the decoder token stream.  GELU MLPs,
LayerNorm, learned-position-free (sinusoidal treated as part of the stub).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab_size=51865, n_enc_layers=6, enc_seq=1500,
    norm="layernorm", mlp="gelu", source="arXiv:2212.04356; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-base-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=256, n_enc_layers=2,
    enc_seq=30, norm="layernorm", mlp="gelu", q_chunk=32,
)
