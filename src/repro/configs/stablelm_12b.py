"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
Source: [hf:stabilityai/stablelm-2-1_6b; hf] — StableLM-2 family: partial
rotary (25%), LayerNorm, per-layer parallel residual omitted (simple pre-norm).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120, n_heads=32,
    n_kv_heads=8, d_ff=13824, vocab_size=100352, partial_rotary=0.25,
    norm="layernorm", qkv_bias=False, rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-12b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, partial_rotary=0.25,
    norm="layernorm", rope_theta=10000.0, q_chunk=32,
)
