"""Model/run configuration system.

`ModelConfig` is a frozen dataclass covering every assigned architecture
family (dense / ssm / hybrid / moe / encdec / vlm).  Each architecture file in
this package exports `CONFIG` (the exact published configuration) and
`SMOKE_CONFIG` (a reduced same-family configuration for CPU smoke tests).

`SHAPES` defines the assigned input-shape set for LM-family architectures;
`CELLS` enumerates the (arch x shape) dry-run cells including the documented
long_500k skips for pure full-attention architectures (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    mlp: str = "swiglu"
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_heads: int = 0               # mamba2 heads
    # hybrid (zamba2): one shared attention block every `attn_every` ssm layers
    attn_every: int = 0
    sliding_window: int = 0          # used by hybrid attn at long context
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                 # precomputed frame embeddings length
    # vlm
    n_vision_tokens: int = 0
    # numerics / execution
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)
    q_chunk: int = 1024
    source: str = ""                 # provenance tag from the assignment table

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding shards evenly
        over any model axis <= 256 (Megatron-style vocab padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND roofline."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            attn = d * self.head_dim_ * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.head_dim_ * d
            mlp = 3 * d * self.d_ff
            return emb + L * (attn + mlp)
        if self.family == "moe":
            attn = d * self.head_dim_ * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.head_dim_ * d
            moe = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            return emb + L * (attn + moe)
        if self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            blk = d * 2 * di + di * (self.d_conv + 2 * N + 2) + di * N + di * d
            return emb + L * blk
        if self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            blk = d * 2 * di + di * (self.d_conv + 2 * N + 2) + di * N + di * d
            attn = 4 * d * d + 3 * d * self.d_ff
            return emb + L * blk + attn
        if self.family == "encdec":
            enc = self.n_enc_layers * (4 * d * d + 2 * d * self.d_ff)
            dec = L * (8 * d * d + 2 * d * self.d_ff)
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.head_dim_ * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.head_dim_ * d
        moe = self.top_k * 3 * d * self.moe_d_ff + d * self.n_experts
        return emb + L * (attn + moe)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

ARCH_IDS = (
    "stablelm-12b", "llama3.2-1b", "glm4-9b", "qwen2.5-14b", "falcon-mamba-7b",
    "internvl2-2b", "zamba2-1.2b", "qwen3-moe-235b-a22b", "granite-moe-1b-a400m",
    "whisper-base",
)

# Families with sub-quadratic sequence mixing run long_500k; pure
# full-attention archs skip it (DESIGN.md §5).
LONG_CONTEXT_ARCHS = ("falcon-mamba-7b", "zamba2-1.2b")


def cell_runnable(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    """Whether a dry-run cell is lowered, and the reason if skipped."""
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: O(L^2) attention unrepresentable at 524288 (DESIGN.md §5)"
    return True, ""


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_cells():
    for a in ARCH_IDS:
        for s in SHAPES:
            yield a, s.name, *cell_runnable(a, s.name)
