"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
Source: [hf:meta-llama/Llama-3.2-1B; unverified] — tied embeddings, theta 500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048, n_heads=32,
    n_kv_heads=8, d_ff=8192, vocab_size=128256, rope_theta=500000.0,
    tie_embeddings=True, source="hf:meta-llama/Llama-3.2-1B; unverified",
)

SMOKE_CONFIG = ModelConfig(
    name="llama3.2-1b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, rope_theta=500000.0,
    tie_embeddings=True, q_chunk=32,
)
