"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
Source: [hf:Qwen/Qwen2.5-0.5B; hf] — GQA with QKV bias.
Note: 40 heads is not divisible by the 16-way model axis; GSPMD pads the head
dimension (documented in EXPERIMENTS.md §Roofline for this arch).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=13824, vocab_size=152064, qkv_bias=True,
    rope_theta=1000000.0, source="hf:Qwen/Qwen2.5-0.5B; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2.5-14b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, qkv_bias=True, q_chunk=32,
)
