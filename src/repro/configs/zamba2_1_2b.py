"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=32000,
ssm_state=64.  Source: [arXiv:2411.15242; hf] — Mamba-2 backbone with a single
*shared* attention block invoked every `attn_every` SSM layers (Zamba2 pattern).
At long_500k the shared block uses a 4096-token sliding window (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64, d_conv=4,
    expand=2, ssm_heads=32, attn_every=6, sliding_window=4096,
    source="arXiv:2411.15242; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=160, vocab_size=256, ssm_state=8, d_conv=4, expand=2,
    ssm_heads=4, attn_every=2, sliding_window=64, q_chunk=32,
)
