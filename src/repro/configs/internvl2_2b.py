"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
Source: [arXiv:2404.16821; hf] — InternViT frontend (STUB: input_specs provides
precomputed patch embeddings, 256 tokens/image) + InternLM2-style dense GQA
backbone.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab_size=92553, n_vision_tokens=256,
    source="arXiv:2404.16821; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-2b-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, n_vision_tokens=8, q_chunk=32,
)
