"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  Source: [hf:Qwen/Qwen3-30B-A3B; hf].
Expert parallelism: 128 experts over the 16-way model axis (8 per chip);
remaining expert-weight dims FSDP-sharded over the data axis (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=0, moe_d_ff=1536,
    vocab_size=151936, n_experts=128, top_k=8, rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, moe_d_ff=32, vocab_size=256, n_experts=8,
    top_k=2, q_chunk=32,
)
