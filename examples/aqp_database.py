"""Database scenario (paper §4.3): a multi-column fact table served by KDE
synopses — per-column 1-D aggregates, multi-column box predicates answered
from a joint synopsis (eq. 11 product kernel, BoxQueryBatch), a 2-D box
COUNT with a full LSCV_H bandwidth matrix, and cross-host synopsis merging
(the fleet-scale story).

    PYTHONPATH=src python examples/aqp_database.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import BoxQuery, KDESynopsis  # noqa: E402
from repro.data import TelemetryStore  # noqa: E402


def main():
    rng = np.random.default_rng(7)
    n = 500_000
    # fact table: amount (skewed), latency_ms (bimodal), discount (bounded)
    amount = rng.lognormal(4.0, 0.8, n).astype(np.float32)
    latency = np.where(rng.random(n) < 0.7, rng.normal(40, 8, n),
                       rng.normal(160, 30, n)).astype(np.float32)

    print("== 1-D aggregates (eqs. 9-10, closed-form Gaussian integrals) ==")
    syn_amt = KDESynopsis.fit(jnp.asarray(amount), selector="plugin", max_sample=2048)
    sel = (amount >= 50) & (amount <= 150)
    print(f"COUNT(50<=amount<=150): ~{float(syn_amt.count(50, 150)):,.0f} "
          f"exact {sel.sum():,}")
    print(f"SUM  (50<=amount<=150): ~{float(syn_amt.sum(50, 150)):,.0f} "
          f"exact {amount[sel].sum():,.0f}")

    print("\n== tail query on a bimodal column (selector quality matters) ==")
    for selector in ["silverman", "plugin", "lscv_h"]:
        syn = KDESynopsis.fit(jnp.asarray(latency), selector=selector, max_sample=2048)
        approx = float(syn.count(120, 250))
        exact = float(((latency >= 120) & (latency <= 250)).sum())
        print(f"  {selector:10s} COUNT(120..250) ~ {approx:9.0f} "
              f"(exact {exact:9.0f}, err {abs(approx - exact) / exact:6.2%})")

    print("\n== 2-D box count with full bandwidth matrix (LSCV_H) ==")
    joint = np.stack([np.log(amount), latency / 100.0], axis=1).astype(np.float32)
    syn2 = KDESynopsis.fit(jnp.asarray(joint), selector="lscv_H", max_sample=512)
    lo, hi = [3.5, 0.2], [5.0, 0.8]
    inbox = ((joint >= lo) & (joint <= hi)).all(axis=1).sum()
    print(f"COUNT(box) ~ {float(syn2.count_box(lo, hi)):,.0f} exact {inbox:,}")

    print("\n== batched query engine: 1000 mixed queries, one pass/column ==")
    import time
    from repro.launch.serve import make_query_mix
    store = TelemetryStore(capacity=2048, seed=0)
    store.track_joint(("amount", "latency"))   # rows sampled from registration on
    store.add_batch({"amount": amount, "latency": latency})
    queries = make_query_mix(1000, {"amount": (50.0, 1000.0),
                                    "latency": (20.0, 250.0)}, seed=11)
    store.query_batch(queries)                # warm-up: fit synopses + compile
    t0 = time.perf_counter()
    answers = store.query_batch(queries)
    dt = time.perf_counter() - t0
    print(f"answered {len(queries)} queries in {dt * 1e3:.1f} ms "
          f"({len(queries) / dt:,.0f} queries/s)")
    for q, ans in list(zip(queries, answers))[:3]:
        print(f"  {q.op.upper():5s}({q.column}) [{q.a:7.1f}, {q.b:7.1f}] ~= {ans:,.1f}")

    print("\n== multi-column predicates from the joint synopsis (eq. 11) ==")
    # SQL:  SELECT COUNT(*), SUM(amount), AVG(latency) FROM facts
    #       WHERE 50 <= amount <= 300 AND 20 <= latency <= 60
    cols = ("amount", "latency")
    box = dict(lo=(50.0, 20.0), hi=(300.0, 60.0))
    box_queries = [
        BoxQuery("count", columns=cols, **box),
        BoxQuery("sum", columns=cols, target="amount", **box),
        BoxQuery("avg", columns=cols, target="latency", **box),
    ]
    box_answers = store.query_box_batch(box_queries)
    sel2 = (amount >= 50) & (amount <= 300) & (latency >= 20) & (latency <= 60)
    print(f"COUNT(*)     ~ {box_answers[0]:12,.0f}  exact {sel2.sum():12,}")
    print(f"SUM(amount)  ~ {box_answers[1]:12,.0f}  exact {amount[sel2].sum():12,.0f}")
    print(f"AVG(latency) ~ {box_answers[2]:12,.2f}  exact {latency[sel2].mean():12,.2f}")

    print("\n== mergeable synopses across 4 'hosts' ==")
    stores = []
    for h in range(4):
        st = TelemetryStore(capacity=1024, seed=h)
        st.add_batch({"latency": latency[h::4]})
        stores.append(st)
    merged = stores[0]
    for st in stores[1:]:
        merged = merged.merge(st)
    frac = merged.fraction("latency", 120, 250, selector="silverman")
    print(f"merged fraction(120..250) ~ {frac:.4f} "
          f"exact {((latency >= 120) & (latency <= 250)).mean():.4f}")


if __name__ == "__main__":
    main()
