"""Database scenario (paper §4.3): a multi-column fact table served by KDE
synopses through the unified declarative API — one `AqpQuery` spec for 1-D
ranges, multi-column boxes (eq. 11 product kernel), categorical equality on
a dictionary column, and GROUP BY, all answered by a single
`QueryEngine.execute` call; plus a 2-D box COUNT with a full LSCV_H
bandwidth matrix and cross-host synopsis merging (the fleet-scale story).

    PYTHONPATH=src python examples/aqp_database.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (AqpQuery, Box, Eq, KDESynopsis,  # noqa: E402
                        Range)
from repro.data import TelemetryStore  # noqa: E402


def main():
    rng = np.random.default_rng(7)
    n = 500_000
    # fact table: amount (skewed), latency_ms (bimodal), discount (bounded)
    amount = rng.lognormal(4.0, 0.8, n).astype(np.float32)
    latency = np.where(rng.random(n) < 0.7, rng.normal(40, 8, n),
                       rng.normal(160, 30, n)).astype(np.float32)

    print("== 1-D aggregates (eqs. 9-10, closed-form Gaussian integrals) ==")
    syn_amt = KDESynopsis.fit(jnp.asarray(amount), selector="plugin", max_sample=2048)
    sel = (amount >= 50) & (amount <= 150)
    print(f"COUNT(50<=amount<=150): ~{float(syn_amt.count(50, 150)):,.0f} "
          f"exact {sel.sum():,}")
    print(f"SUM  (50<=amount<=150): ~{float(syn_amt.sum(50, 150)):,.0f} "
          f"exact {amount[sel].sum():,.0f}")

    print("\n== tail query on a bimodal column (selector quality matters) ==")
    for selector in ["silverman", "plugin", "lscv_h"]:
        syn = KDESynopsis.fit(jnp.asarray(latency), selector=selector, max_sample=2048)
        approx = float(syn.count(120, 250))
        exact = float(((latency >= 120) & (latency <= 250)).sum())
        print(f"  {selector:10s} COUNT(120..250) ~ {approx:9.0f} "
              f"(exact {exact:9.0f}, err {abs(approx - exact) / exact:6.2%})")

    print("\n== 2-D box count with full bandwidth matrix (LSCV_H) ==")
    joint = np.stack([np.log(amount), latency / 100.0], axis=1).astype(np.float32)
    syn2 = KDESynopsis.fit(jnp.asarray(joint), selector="lscv_H", max_sample=512)
    lo, hi = [3.5, 0.2], [5.0, 0.8]
    inbox = ((joint >= lo) & (joint <= hi)).all(axis=1).sum()
    print(f"COUNT(box) ~ {float(syn2.count_box(lo, hi)):,.0f} exact {inbox:,}")

    print("\n== unified engine: one mixed batch, one execute call ==")
    import time
    from repro.launch.serve import make_mixed_aqp_queries
    store = TelemetryStore(capacity=2048, seed=0)
    store.track_joint(("amount", "latency"))   # rows sampled from registration on
    # region is dictionary-coded (0=na, 1=emea, 2=apac): Eq/GROUP BY territory
    region = rng.integers(0, 3, n).astype(np.float32)
    # registered before data: Eq terms on region answer EXACTLY from the
    # per-code frequency sketch instead of the KDE code window
    store.track_categorical("region")
    store.add_batch({"amount": amount, "latency": latency, "region": region})
    # registered AFTER add_batch: the joint reservoir is backfilled from the
    # per-column reservoirs (marginals right away; correlations stream in)
    store.track_joint(("region", "amount"))
    queries = make_mixed_aqp_queries(
        1000, {"amount": (50.0, 1000.0), "latency": (20.0, 250.0)},
        ("amount", "latency"), "region", (0.0, 1.0, 2.0), seed=11)
    engine = store.engine()
    engine.execute(queries)                   # warm-up: fit synopses + compile
    t0 = time.perf_counter()
    results = engine.execute(queries)
    dt = time.perf_counter() - t0
    from collections import Counter
    paths = Counter(r.path for r in results)
    print(f"answered {len(results)} mixed queries in {dt * 1e3:.1f} ms "
          f"({len(results) / dt:,.0f} queries/s) -- paths: {dict(paths)}")

    print("\n== declarative specs: box, Eq, GROUP BY in the same batch ==")
    # SQL:  SELECT COUNT(*), SUM(amount), AVG(latency) FROM facts
    #       WHERE 50 <= amount <= 300 AND 20 <= latency <= 60;
    #       SELECT COUNT(*) FROM facts WHERE region = 2;
    #       SELECT region, COUNT(*) FROM facts
    #         WHERE 50 <= amount <= 300 GROUP BY region;
    box = Box(("amount", "latency"), lo=(50.0, 20.0), hi=(300.0, 60.0))
    specs = [
        AqpQuery("count", (box,)),
        AqpQuery("sum", (box,), target="amount"),
        AqpQuery("avg", (box,), target="latency"),
        AqpQuery("count", (Eq("region", 2),)),
        AqpQuery("count", (Range("amount", 50.0, 300.0),), group_by="region"),
    ]
    res = engine.execute(specs)
    sel2 = (amount >= 50) & (amount <= 300) & (latency >= 20) & (latency <= 60)
    print(f"COUNT(*)        ~ {res[0].estimate:12,.0f}  exact {sel2.sum():12,}")
    print(f"SUM(amount)     ~ {res[1].estimate:12,.0f}  "
          f"exact {amount[sel2].sum():12,.0f}")
    print(f"AVG(latency)    ~ {res[2].estimate:12,.2f}  "
          f"exact {latency[sel2].mean():12,.2f}")
    print(f"COUNT(region=2) ~ {res[3].estimate:12,.0f}  "
          f"exact {(region == 2).sum():12,}")
    for r in res[4:]:
        ex = ((amount >= 50) & (amount <= 300) & (region == r.group)).sum()
        print(f"  region={r.group:.0f}: COUNT ~ {r.estimate:10,.0f}  "
              f"exact {ex:10,}  [{r.path}]")

    print("\n== streaming admission: futures + cross-caller micro-batches ==")
    # Many logical clients submit independently; the session coalesces their
    # specs into micro-batches and flushes on watermark/deadline — answers
    # are bit-identical to engine.execute for the same specs.
    with store.session(watermark=8, max_delay=0.005) as session:
        futures = [session.submit(q) for q in specs[:4]]
        answers = [f.result() for f in futures]
    st = session.stats()
    for r, label in zip(answers, ("COUNT(box)", "SUM(amount)",
                                  "AVG(latency)", "COUNT(region=2)")):
        print(f"  {label:16s} ~ {r.estimate:12,.2f}  [{r.path}]")
    print(f"  {st['flushes']} flushes ({st['mean_batch']:.1f} mean batch), "
          f"reasons {st['flush_reasons']}")

    print("\n== mergeable synopses across 4 'hosts' ==")
    stores = []
    for h in range(4):
        st = TelemetryStore(capacity=1024, seed=h)
        st.add_batch({"latency": latency[h::4]})
        stores.append(st)
    merged = stores[0]
    for st in stores[1:]:
        merged = merged.merge(st)
    frac = merged.fraction("latency", 120, 250, selector="silverman")
    print(f"merged fraction(120..250) ~ {frac:.4f} "
          f"exact {((latency >= 120) & (latency <= 250)).mean():.4f}")


if __name__ == "__main__":
    main()
