"""Quickstart: KDE-based approximate query processing in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a KDE synopsis over a synthetic 'sales' column with each of the
paper's three bandwidth-selector classes, then answers COUNT/SUM/AVG range
queries approximately and compares with the exact answers.
"""
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import KDESynopsis  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    # a 1M-row relation: order values, lognormal-ish (retail-like skew)
    sales = rng.lognormal(mean=3.0, sigma=0.7, size=1_000_000).astype(np.float32)

    queries = [(10.0, 40.0), (20.0, 60.0), (5.0, 15.0)]
    for selector in ["silverman", "plugin", "lscv_h"]:
        syn = KDESynopsis.fit(jnp.asarray(sales), selector=selector, max_sample=2048)
        print(f"\nselector = {selector}  (synopsis: {syn.x.size} points "
              f"~ {syn.x.size / sales.size:.4%} of the relation)")
        for a, b in queries:
            c_apx = float(syn.count(a, b))
            s_apx = float(syn.sum(a, b))
            sel = (sales >= a) & (sales <= b)
            c_ex, s_ex = float(sel.sum()), float(sales[sel].sum())
            print(f"  WHERE {a:5.1f} <= sales <= {b:5.1f}  "
                  f"COUNT ~ {c_apx:12.0f} (exact {c_ex:12.0f}, "
                  f"err {abs(c_apx - c_ex) / c_ex:6.2%})   "
                  f"AVG ~ {s_apx / c_apx:7.2f} (exact {s_ex / c_ex:7.2f})")


if __name__ == "__main__":
    main()
