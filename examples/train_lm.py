"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU, with checkpointing, AQP telemetry, and a restart demo.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

--tiny shrinks the model (for quick verification); the default is a ~100M
llama-style config (12L x 768, vocab 32768).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.data import TelemetryStore, TokenPipeline  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train import make_train_step  # noqa: E402


def config(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(name="demo-tiny", family="dense", n_layers=2,
                           d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                           vocab_size=1024, q_chunk=64)
    # ~100M params: 12 x (4*768^2 + 3*768*2048) + 2*32768*768 ~ 135M
    return ModelConfig(name="demo-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab_size=32768, q_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config(args.tiny)
    model = build_model(cfg)
    n_params = sum(p.size for p in jax.tree.leaves(model.init(jax.random.key(0))))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params")

    params = model.init(jax.random.key(0))
    opt_state = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    telemetry = TelemetryStore()
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, telemetry=telemetry)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    t0 = time.time()
    for step in range(args.steps):
        batch = pipe.next()
        params, opt_state, m = step_fn(params, opt_state, batch)
        telemetry.add_batch({"loss": np.asarray([float(m["loss"])], np.float32)})
        if step % 20 == 0 or step == args.steps - 1:
            toks = args.batch * args.seq * (step + 1)
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  {toks / (time.time() - t0):,.0f} tok/s")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, (params, opt_state),
                      {"step": step + 1, "pipeline": pipe.state()})
    ckpt.wait()

    # AQP over the training history (the paper's technique, in the loop)
    losses = telemetry.columns["loss"]
    lo, hi = losses.sample().min(), losses.sample().max()
    mid = (lo + hi) / 2
    print(f"[aqp] P(loss <= {mid:.2f}) ~ "
          f"{telemetry.fraction('loss', float(lo) - 1, float(mid), selector='silverman'):.3f} "
          f"over {losses.n_seen} recorded steps")
    print("[example] done")


if __name__ == "__main__":
    main()
