"""Distributed bandwidth selection on an 8-device placeholder mesh — the
paper's O(n^2) selectors block-row-sharded over chips (DESIGN.md §2, last
table row).  On a real pod this is the same code with a real mesh.

    PYTHONPATH=src python examples/distributed_bandwidth.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, "src")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import gaussian as G  # noqa: E402
from repro.core import lscv_h  # noqa: E402
from repro.core.distributed import (distributed_lscv_h,  # noqa: E402
                                    sharded_pairwise_reduce)
from repro.core.reductions import pairwise_reduce  # noqa: E402


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")
    rng = np.random.default_rng(0)

    n = 20_000
    x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    fun = lambda d: G.k4(d / 0.2)
    t0 = time.time()
    dist = float(sharded_pairwise_reduce(fun, x, mesh))
    t_dist = time.time() - t0
    t0 = time.time()
    single = float(pairwise_reduce(fun, x))
    t_single = time.time() - t0
    print(f"pairwise K4 sum  n={n}: sharded={dist:.4f} ({t_dist:.2f}s) "
          f"single={single:.4f} ({t_single:.2f}s) rel_err="
          f"{abs(dist - single) / abs(single):.1e}")

    x2 = jnp.asarray(rng.normal(0, 1, (3000, 4)).astype(np.float32))
    h, grid, g = distributed_lscv_h(x2, mesh, n_h=50)
    ref = lscv_h(x2, n_h=50)
    print(f"distributed LSCV_h n=3000 d=4: h={float(h):.4f} "
          f"(single-path h={float(ref.h):.4f})")


if __name__ == "__main__":
    main()
