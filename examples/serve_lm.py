"""Serving scenario: batched prefill + greedy decode with KV caches, for a
dense LM and an attention-free SSM (O(1) decode state) side by side.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import greedy_generate  # noqa: E402


def demo(arch: str, batch=4, prompt_len=12, max_new=12):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (batch, prompt_len),
                                0, cfg.vocab_size, jnp.int32)
    extra = None
    if cfg.family == "encdec":   # audio frontend stub: precomputed frames
        extra = {"enc_frames": jnp.ones((batch, cfg.enc_seq, cfg.d_model),
                                        jnp.bfloat16)}
    t0 = time.time()
    out = greedy_generate(model, params, prompt, max_new, extra_batch=extra)
    dt = time.time() - t0
    print(f"[{arch:18s}] generated {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:.1f}s; sample: {out[0, prompt_len:].tolist()}")


def main():
    demo("llama3.2-1b")        # dense GQA: growing KV cache
    demo("falcon-mamba-7b")    # SSM: constant-size state
    demo("whisper-base")       # enc-dec: self + cross caches


if __name__ == "__main__":
    main()
